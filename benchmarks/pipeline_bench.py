"""Pipeline benchmark: decision hiding + lookahead window dedup.

Two sweeps over a Zipf-1.2 CTR stream (the skew regime the paper's
workloads live in), written to benchmarks/results/BENCH_pipeline.json:

  * ``depth`` — synchronous (pipeline_depth=1) vs pipelined (depth=2)
    ESD simulation with the dispatch decision *comparable to* the
    training stage (the regime where hiding matters): per-iteration time
    must land at ~max(train_stage, decision) instead of their sum, and
    the end-to-end ItpS speedup must clear 1.2x.

  * ``lookahead`` — miss-op reduction as the window W grows: the W-batch
    dedup window shields soon-reused latest copies from eviction
    (Belady-graded, core.cache ``protect=``), so the cache engine itself
    reports fewer miss pulls; the sweep records the monotone drop and
    the window's dedup fraction.

Plus a ``runner`` smoke: the jitted decide/advance/train stages of the
real train driver at depth 1 vs 2 on this host (one CPU device — the
numbers show overhead parity, not overlap; true overlap needs parallel
device streams).

``--quick`` runs a reduced sweep into BENCH_pipeline_quick.json
(untracked) so CI smoke never clobbers the tracked record.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import SimConfig, simulate
from repro.data.synthetic import CTRWorkload

RESULTS = Path(__file__).parent / "results"


def _workload(a: float = 1.2) -> CTRWorkload:
    return CTRWorkload(name=f"zipf{a}", model="wdl",
                       table_sizes=(50_000,) * 4 + (1_000,) * 8,
                       zipf_a=(a,) * 12, hist_max=8, hist_mean=4.0)


def bench_depth(iters: int, m: int = 128, alpha: float = 0.25) -> dict:
    """Synchronous vs pipelined step time with the decision stage sized
    comparable to the training stage (compute_time ~ calibrated Table-2
    decision latency at this m*alpha)."""
    from repro.core.simulator import calibrated_decision_time

    wl = _workload()
    dec = calibrated_decision_time(m, alpha)
    base = dict(workload=wl, n_workers=8, batch_per_worker=m,
                cache_ratio=0.02, iters=iters, warmup=max(2, iters // 5),
                mechanism="esd", alpha=alpha, compute_time_s=dec)
    sync = simulate(SimConfig(pipeline_depth=1, **base))
    pipe = simulate(SimConfig(pipeline_depth=2, **base))
    # the pipelined per-iteration time vs the ideal max(train, decision)
    ideal = np.maximum(
        pipe.pipeline["train_stage_mean_s"],
        pipe.pipeline["decision_stage_mean_s"])
    return {
        "m": m, "alpha": alpha, "decision_s": dec,
        "sync_itps": sync.itps, "pipe_itps": pipe.itps,
        "speedup": pipe.itps / sync.itps,
        "pipe_iter_mean_s": float(np.mean(pipe.per_iter_time)),
        "ideal_max_s": float(ideal),
        "hidden_ratio": float(np.mean(pipe.per_iter_time)) / float(
            np.mean(sync.per_iter_time)),
    }


def bench_lookahead(iters: int, windows=(0, 2, 4, 8)) -> dict:
    """Miss-op reduction vs window size under Zipf 1.2 (tight LRU cache,
    eviction pressure — where the shield can act)."""
    wl = _workload()
    base = dict(workload=wl, n_workers=8, batch_per_worker=64,
                cache_ratio=0.005, iters=iters, warmup=max(2, iters // 5),
                mechanism="esd", alpha=0.0, policy="lru")
    rows = []
    for W in windows:
        r = simulate(SimConfig(lookahead=W, **base))
        p = r.pipeline
        rows.append({
            "W": W,
            "miss_pull": p["miss_pull_total"],
            "cost": r.cost,
            "hit_ratio": r.hit_ratio,
            "dedup_frac": (p["dedup_saved_ops"]
                           / max(p["dedup_total_touches"], 1)),
        })
    base_miss = max(rows[0]["miss_pull"], 1)
    for row in rows:
        row["miss_reduction"] = 1.0 - row["miss_pull"] / base_miss
    return {"windows": list(windows), "rows": rows,
            "monotone": all(rows[i + 1]["miss_pull"] <= rows[i]["miss_pull"]
                            for i in range(len(rows) - 1))}


def bench_runner(steps: int = 6) -> dict:
    """Wall-clock smoke of the real jitted stage pipeline (train driver)
    at depth 1 vs 2 — overhead parity on one CPU device."""
    from repro.launch.train import main

    res = {}
    for depth in (1, 2):
        t0 = time.perf_counter()
        metrics = main(["--arch", "wdl-tiny", "--steps", str(steps),
                        "--batch-per-worker", "16", "--esd-alpha", "0",
                        "--pipeline-depth", str(depth)])
        res[f"depth{depth}"] = {
            "wall_s": time.perf_counter() - t0,
            "final_loss": metrics[-1]["loss"],
        }
    res["bitwise_equal"] = (res["depth1"]["final_loss"]
                            == res["depth2"]["final_loss"])
    return res


def run(quick: bool = False, out: Path | None = None) -> dict:
    if out is None:
        out = RESULTS / ("BENCH_pipeline_quick.json" if quick
                         else "BENCH_pipeline.json")
    iters = 12 if quick else 40
    # full run: the paper's alpha=1 regime (decision ~ a full train step,
    # the strongest hiding case); quick: alpha=0.5 keeps the host-side
    # solver cheap while still clearing the 1.2x bar
    report = {
        "config": {"zipf_a": 1.2, "iters": iters},
        "depth": bench_depth(iters, alpha=0.5 if quick else 1.0),
        "lookahead": bench_lookahead(iters,
                                     windows=(0, 4) if quick else (0, 2, 4, 8)),
    }
    if not quick:
        report["runner"] = bench_runner()
    d = report["depth"]
    print(f"pipeline.depth,{d['speedup'] * 100:.0f},"
          f"speedup={d['speedup']:.2f}x,"
          f"iter={d['pipe_iter_mean_s'] * 1e3:.1f}ms,"
          f"ideal_max={d['ideal_max_s'] * 1e3:.1f}ms")
    for row in report["lookahead"]["rows"]:
        print(f"pipeline.W{row['W']},{row['miss_pull']},"
              f"miss_red={row['miss_reduction']:.2%},"
              f"dedup={row['dedup_frac']:.2f}")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
