"""Roofline analysis (deliverable g): three terms per (arch x shape), from
the dry-run JSONs in benchmarks/results/.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
  memory term     = HLO_bytes_per_device / HBM_bw             [s]
  collective term = collective_bytes_per_device / link_bw     [s]

(cost_analysis reports per-DEVICE quantities under SPMD — calibrated in
EXPERIMENTS.md §Dry-run — so the "/ chips" in the assignment's formulas is
already applied.)  HLO flops/bytes use the scan-trip-count-corrected
extrapolations.  MODEL_FLOPS = 6*N*D for training (2*N*D for single
forward; N = active params for MoE), and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs * chips) flags remat/replication waste.

CPU-backend caveat (documented): XLA-CPU upcasts bf16 matmuls to f32, so
"bytes accessed" is ~2x a real TPU lowering; collective byte counts parse
the post-SPMD HLO and are dtype-accurate.
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).parent / "results"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(rec: dict) -> float:
    from repro.configs import CONFIGS, INPUT_SHAPES
    cfg = CONFIGS[rec["arch"]]
    shape = INPUT_SHAPES[rec["shape"]]
    n_active = rec.get("active_params", cfg.param_count())
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # one decoded token


def analyze(rec: dict) -> dict | None:
    if "skipped" in rec:
        return None
    ca = rec.get("cost_analysis_extrapolated") or rec.get("cost_analysis")
    if not isinstance(ca, dict):
        return None
    coll = rec.get("collectives_extrapolated") or rec.get("collectives") or {}
    chips = CHIPS[rec["mesh"]]
    flops_dev = ca.get("flops", 0.0)
    bytes_dev = ca.get("bytes accessed", 0.0)
    coll_dev = coll.get("total_bytes", 0.0)

    compute_t = flops_dev / PEAK_FLOPS
    # memory bounds: HLO "bytes accessed" counts EVERY op's operands/results
    # (no fusion, f32-upcast) -> loose UPPER bound; the lower bound reads
    # the resident state (weights/opt/cache) once.
    memory_hi = bytes_dev / 2.0 / HBM_BW    # /2: CPU-backend f32 upcast
    memory_lo = rec.get("state_bytes_per_device", 0.0) / HBM_BW
    coll_t = coll_dev / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_lo,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    terms["memory_hi_s"] = memory_hi
    mf = model_flops(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": round(mf / max(flops_dev * chips, 1.0), 3),
        "state_gib_per_device": round(
            rec.get("state_bytes_per_device", 0) / 2**30, 2),
        "attn_mode": rec.get("attn_mode", "?"),
    }


def load_all(mesh: str = "16x16") -> list[dict]:
    rows = []
    for f in sorted(RESULTS.glob(f"{mesh}_*.json")):
        rec = json.loads(f.read_text())
        row = analyze(rec)
        if row:
            rows.append(row)
        elif "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "skipped": rec["skipped"]})
    return rows


def table(mesh: str = "16x16") -> str:
    rows = load_all(mesh)
    hdr = ("arch,shape,compute_s,memory_s,memory_hi_s,collective_s,dominant,"
           "useful_ratio,state_GiB/dev,attn_mode")
    lines = [hdr]
    for r in rows:
        if "skipped" in r:
            lines.append(f"{r['arch']},{r['shape']},SKIP({r['skipped'][:40]}...)")
            continue
        lines.append(
            f"{r['arch']},{r['shape']},{r['compute_s']:.4f},{r['memory_s']:.4f},"
            f"{r['memory_hi_s']:.4f},"
            f"{r['collective_s']:.4f},{r['dominant']},{r['useful_ratio']},"
            f"{r['state_gib_per_device']},{r['attn_mode']}"
        )
    return "\n".join(lines)


def main():
    for mesh in ("16x16",):
        t = table(mesh)
        print(t)
        (RESULTS / f"roofline_{mesh}.csv").write_text(t + "\n")


if __name__ == "__main__":
    main()
