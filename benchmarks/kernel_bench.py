"""Kernel micro-bench: Pallas (interpret) vs jnp reference.

Interpret-mode timings are NOT TPU performance — they validate that the
kernels run and give a per-call cost for the CI log.  On TPU hardware the
same pallas_call compiles natively (interpret=False).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels.emb_lookup import pooled_lookup
from repro.kernels.ref import pooled_lookup_ref


def _t(fn, *a):
    fn(*a)
    t0 = time.perf_counter()
    fn(*a)
    return (time.perf_counter() - t0) * 1e6


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    sizes = [(64, 8, 5000, 128)]
    if not quick:                 # the big config takes minutes interpreted
        sizes.append((256, 26, 20000, 512))
    for B, F, V, E in sizes:
        table = jnp.asarray(rng.standard_normal((V, E)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, V, (B, F)), jnp.int32)
        us_k = _t(lambda t, i: pooled_lookup(t, i).block_until_ready(), table, ids)
        us_b = _t(lambda t, i: pooled_lookup(t, i, block_f=8)
                  .block_until_ready(), table, ids)
        us_r = _t(lambda t, i: pooled_lookup_ref(t, i).block_until_ready(), table, ids)
        print(f"kernel.pooled_lookup.B{B}F{F}E{E}.pallas_interpret,{us_k:.0f},"
              f"blocked_us={us_b:.0f},ref_us={us_r:.0f}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
