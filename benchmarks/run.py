"""Benchmark harness entrypoint: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV; caches everything under
benchmarks/results/; the roofline table is regenerated from whatever
dry-run JSONs exist (run repro.launch.dryrun first for the full 40).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="figs 4-6 only, fewer sizes")
    ap.add_argument("--only", default=None,
                    help="comma-list: table2,paper,kernels,dispatch,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    t0 = time.perf_counter()

    if only is None or "table2" in only:
        from . import table2
        table2.run(serial_max_bpw=64 if args.quick else 128,
                   parallel_max_bpw=128 if args.quick else 512)

    if only is None or "paper" in only:
        from . import paper_experiments
        paper_experiments.run_all(quick=args.quick)

    if only is None or "emark" in only:
        from . import emark_ablation
        emark_ablation.run()

    if only is None or "kernels" in only:
        from . import kernel_bench
        kernel_bench.run(quick=args.quick)

    if only is None or "dispatch" in only:
        from . import dispatch_bench
        dispatch_bench.run(quick=args.quick)

    if only is None or "roofline" in only:
        from . import roofline
        try:
            roofline.main()
        except Exception as e:  # dry-run results may not exist yet
            print(f"roofline,SKIP,{type(e).__name__}:{e}", file=sys.stderr)

    print(f"total_wall,{(time.perf_counter() - t0) * 1e6:.0f},s="
          f"{time.perf_counter() - t0:.1f}")


if __name__ == "__main__":
    main()
