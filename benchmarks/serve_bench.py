"""Serving benchmark: ESD latency-SLO dispatch vs random at inference QPS.

Virtual-clock serving episodes (:mod:`repro.serve.sim` — deterministic
given the seed, so the gates ride on simulated, not wall-clock, numbers)
on the hetero-bandwidth preset (half the workers on 5 Gbps links, half
on 0.5 Gbps), written to benchmarks/results/BENCH_serve.json:

  * ``reference`` — the headline operating point (qps=9000, slo=5 ms,
    8 workers, E=512, Zipf drift on): ESD's latency-SLO cost must hold
    the SLO-violation rate at <= 5% AND beat random dispatch on both
    p99 latency and violation rate — random keeps landing tail requests
    (plane misses) on slow links that ESD prices out.

  * ``levels`` — the same episode at two QPS levels (half and full
    reference load) under Zipf drift, recording p50/p99, QPS-per-worker
    and plane-staleness age for both mechanisms.

  * ``burst`` — a flash crowd (rate x4 for 0.3 s mid-episode): p99 must
    stay finite and the episode must absorb the burst (all requests
    served).

  * ``driver`` (full runs only) — the real-clock driver
    (repro.launch.serve) at a small QPS on this host: wall-clock p50/p99
    positive-only, proving the jitted plane-served path paces a live
    stream.

``--quick`` runs shortened episodes into BENCH_serve_quick.json
(untracked) so CI smoke never clobbers the tracked record.
"""
from __future__ import annotations

import numpy as np

from repro.core.simulator import SimConfig
from repro.data.synthetic import WORKLOADS
from repro.obs import write_bench
from repro.serve import ServeKnobs, simulate_serve

REF_QPS = 9000.0
REF_SLO_MS = 5.0


def _episode(qps: float, duration: float, *, slo_ms: float = REF_SLO_MS,
             mechanism: str = "esd", burst: bool = False,
             seed: int = 0) -> dict:
    knobs = ServeKnobs(
        qps=qps, duration_s=duration, slo_ms=slo_ms,
        max_batch=32, max_wait_ms=2.0, ttl_s=0.3,
        service_ms=0.4, service_us_per_req=60.0,
        drift_period_s=0.4,
        burst_at_s=duration * 0.4 if burst else None,
        burst_dur_s=0.3 if burst else 0.0,
        burst_x=4.0 if burst else 1.0,
    )
    cfg = SimConfig(workload=WORKLOADS["tiny"], n_workers=8,
                    embedding_dim=512, cache_ratio=0.06,
                    mechanism=mechanism, seed=seed, serve=knobs)
    return simulate_serve(cfg).summary()


def bench_reference(duration: float) -> dict:
    esd = _episode(REF_QPS, duration, mechanism="esd")
    rnd = _episode(REF_QPS, duration, mechanism="random")
    return {
        "qps": REF_QPS, "slo_ms": REF_SLO_MS,
        "esd": esd, "random": rnd,
        "esd_beats_random_p99": esd["p99_ms"] < rnd["p99_ms"],
        "esd_beats_random_slo": (esd["slo_violation_rate"]
                                 < rnd["slo_violation_rate"]),
    }


def bench_levels(duration: float) -> list[dict]:
    out = []
    for qps in (REF_QPS / 2, REF_QPS):
        esd = _episode(qps, duration, mechanism="esd")
        rnd = _episode(qps, duration, mechanism="random")
        out.append({"qps": qps, "esd": esd, "random": rnd,
                    "p99_ratio_random_over_esd":
                        rnd["p99_ms"] / max(esd["p99_ms"], 1e-12)})
    return out


def bench_burst(duration: float) -> dict:
    esd = _episode(REF_QPS, duration, mechanism="esd", burst=True)
    base = _episode(REF_QPS, duration, mechanism="esd", burst=False)
    return {"esd": esd, "baseline_p99_ms": base["p99_ms"],
            "burst_x": 4.0,
            "all_served": esd["n_requests"] > base["n_requests"]}


def bench_driver() -> dict:
    """Real-clock smoke: the launch driver at a tame QPS on this host."""
    from repro.launch.serve import build_parser, run_serve

    args = build_parser().parse_args(
        ["--arch", "wdl-tiny", "--qps", "120", "--duration", "1.0",
         "--slo-ms", "100", "--max-wait-ms", "10"])
    out = run_serve(args)
    return {k: out[k] for k in ("p50_ms", "p99_ms", "mean_ms",
                                "slo_violation_rate", "n_requests")}


def run(quick: bool = False, out=None) -> dict:
    duration = 0.6 if quick else 1.5

    reference = bench_reference(duration)
    levels = bench_levels(duration)
    burst = bench_burst(duration)

    report = {
        "config": {"workload": "tiny", "n_workers": 8,
                   "embedding_dim": 512, "cache_ratio": 0.06,
                   "slo_ms": REF_SLO_MS, "duration_s": duration,
                   "qps_levels": [REF_QPS / 2, REF_QPS],
                   "bandwidths": "hetero default (half 5, half 0.5 Gbps)"},
        "reference": reference,
        "levels": levels,
        "burst": burst,
    }
    if not quick:
        report["driver"] = bench_driver()

    e, r = reference["esd"], reference["random"]
    print(f"serve.reference,qps={REF_QPS:.0f},slo={REF_SLO_MS}ms,"
          f"esd_p99={e['p99_ms']:.2f}ms,random_p99={r['p99_ms']:.2f}ms,"
          f"esd_slo={e['slo_violation_rate']:.4f},"
          f"random_slo={r['slo_violation_rate']:.4f}")
    for lvl in levels:
        print(f"serve.level,qps={lvl['qps']:.0f},"
              f"esd_p99={lvl['esd']['p99_ms']:.2f}ms,"
              f"p99_ratio={lvl['p99_ratio_random_over_esd']:.2f},"
              f"esd_qpw_max={max(lvl['esd']['qps_per_worker']):.0f}")
    print(f"serve.burst,x4,esd_p99={burst['esd']['p99_ms']:.2f}ms,"
          f"baseline_p99={burst['baseline_p99_ms']:.2f}ms,"
          f"n_req={burst['esd']['n_requests']}")
    if "driver" in report:
        d = report["driver"]
        print(f"serve.driver,p99={d['p99_ms']:.2f}ms,"
              f"slo_rate={d['slo_violation_rate']:.4f},"
              f"n_req={d['n_requests']}")

    write_bench("serve", report, quick=quick, out=out)
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
