"""Serve a small model with batched requests: KV-cache decode loop.

  PYTHONPATH=src python examples/serve_llm_decode.py [--arch smollm-360m]

Uses the reduced (smoke) variant of any assigned architecture on CPU:
prefill a batch of prompts token-by-token into the cache, then greedy-
decode continuations — exercising the same serve_step the multi-pod
dry-run lowers at decode_32k / long_500k shapes.  Works across attention,
SSM (falcon-mamba) and hybrid (recurrentgemma) cache types.

Timing flows through the obs metrics registry (per-step wall histogram
-> p50/p99) and progress prints as stable-key-order ``log_step`` lines,
same as the training and serving drivers.
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.models import api  # noqa: E402
from repro.obs import MetricsRegistry, log_step  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    rng = np.random.default_rng(0)
    params = api.init_model(jax.random.key(0), cfg)
    max_len = args.prompt_len + args.gen_len
    cache = api.init_decode_cache(cfg, args.batch, max_len)

    step = jax.jit(lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos))

    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    tok = jnp.asarray(prompts[:, :1], jnp.int32)
    reg = MetricsRegistry()
    step_h = reg.histogram("decode.step_s", keep=True)
    tok_c = reg.counter("decode.tokens")
    t0 = time.perf_counter()
    out_tokens = [np.asarray(tok)]
    for pos in range(max_len - 1):
        ts = time.perf_counter()
        logits, cache = step(params, tok, cache, jnp.asarray(pos, jnp.int32))
        if pos + 1 < args.prompt_len:            # teacher-forced prefill
            tok = jnp.asarray(prompts[:, pos + 1:pos + 2], jnp.int32)
        else:                                     # greedy decode
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
        step_h.observe(time.perf_counter() - ts)
        tok_c.inc(args.batch)
        if pos % 8 == 0 or pos == max_len - 2:
            log_step({"step": pos, "wall_s": round(time.perf_counter() - t0, 4),
                      "phase": "prefill" if pos + 1 < args.prompt_len
                               else "decode",
                      "step_ms": round((time.perf_counter() - ts) * 1e3, 2)},
                     stream=sys.stdout)
    dt = time.perf_counter() - t0
    seq = np.concatenate(out_tokens, axis=1)
    log_step({"wall_s": round(dt, 4),
              "arch": args.arch, "batch": args.batch,
              "steps": max_len - 1,
              "tok_per_s": round(tok_c.value / dt, 1),
              "step_p50_ms": round(step_h.quantile(0.5) * 1e3, 2),
              "step_p99_ms": round(step_h.quantile(0.99) * 1e3, 2)},
             stream=sys.stdout)
    for b in range(min(args.batch, 2)):
        print(f"  seq[{b}] prompt={seq[b, :args.prompt_len].tolist()} "
              f"-> gen={seq[b, args.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
