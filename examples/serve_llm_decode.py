"""Serve a small model with batched requests: KV-cache decode loop.

  PYTHONPATH=src python examples/serve_llm_decode.py [--arch smollm-360m]

Uses the reduced (smoke) variant of any assigned architecture on CPU:
prefill a batch of prompts token-by-token into the cache, then greedy-
decode continuations — exercising the same serve_step the multi-pod
dry-run lowers at decode_32k / long_500k shapes.  Works across attention,
SSM (falcon-mamba) and hybrid (recurrentgemma) cache types.
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.models import api  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    rng = np.random.default_rng(0)
    params = api.init_model(jax.random.key(0), cfg)
    max_len = args.prompt_len + args.gen_len
    cache = api.init_decode_cache(cfg, args.batch, max_len)

    step = jax.jit(lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos))

    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    tok = jnp.asarray(prompts[:, :1], jnp.int32)
    t0 = time.perf_counter()
    out_tokens = [np.asarray(tok)]
    for pos in range(max_len - 1):
        logits, cache = step(params, tok, cache, jnp.asarray(pos, jnp.int32))
        if pos + 1 < args.prompt_len:            # teacher-forced prefill
            tok = jnp.asarray(prompts[:, pos + 1:pos + 2], jnp.int32)
        else:                                     # greedy decode
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    seq = np.concatenate(out_tokens, axis=1)
    print(f"arch={args.arch} (reduced) batch={args.batch} "
          f"steps={max_len - 1} wall={dt:.2f}s "
          f"({(max_len - 1) * args.batch / dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq[{b}] prompt={seq[b, :args.prompt_len].tolist()} "
              f"-> gen={seq[b, args.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
