"""Quickstart: the ESD mechanism on one batch, end to end.

  PYTHONPATH=src python examples/quickstart.py

1. Build a synthetic CTR workload (Criteo-shaped Zipf streams).
2. Compute the Alg.-1 expected-transmission-cost matrix from live cache
   state under heterogeneous bandwidths.
3. Dispatch with HybridDis (Opt+Heu) and compare total expected cost
   against LAIA-style hit-count dispatch and random dispatch.
4. Run the cache protocol one iteration and show the actual miss-pull /
   update-push / evict-push counts.
"""
import numpy as np

from repro.core import (
    ClusterCache, cost_matrix_np, hybrid_dispatch, laia_dispatch,
    random_dispatch, transmission_time,
)
from repro.data.synthetic import WORKLOADS

rng = np.random.default_rng(0)
wl = WORKLOADS["tiny"]
n, m = 4, 32
k = n * m

# heterogeneous edge links: two 5 Gbps workers, two 0.5 Gbps (paper default)
bandwidth = np.array([5e9, 5e9, 0.5e9, 0.5e9]) / 8
t_tran = transmission_time(512 * 4, bandwidth)
print(f"per-embedding transfer cost (s): {t_tran}")

cache = ClusterCache(n, wl.vocab, capacity=int(0.2 * wl.vocab))
stream = wl.stream(seed=1, batch=k)

# warm the caches for a few iterations with random dispatch
for _ in range(5):
    samples, _, _ = next(stream)
    assign = random_dispatch(k, n, rng)
    cache.step([np.unique(samples[assign == j]) for j in range(n)])

samples, _, _ = next(stream)
latest, dirty = cache.snapshot()
C = cost_matrix_np(samples, latest, dirty, t_tran)
print(f"\ncost matrix: shape={C.shape}, mean={C.mean():.4g}, "
      f"row spread={np.mean(C.max(1) - C.min(1)):.4g}")

plans = {
    "ESD(alpha=1)": hybrid_dispatch(C, m, alpha=1.0, opt="ssp"),
    "ESD(alpha=0) [Heu]": hybrid_dispatch(C, m, alpha=0.0),
    "LAIA": laia_dispatch(samples, cache.latest_in_cache, m),
    "random": random_dispatch(k, n, rng),
}
print("\nexpected transmission cost by dispatch plan:")
for name, a in plans.items():
    print(f"  {name:20s} {C[np.arange(k), a].sum():.5f} s")

best = plans["ESD(alpha=1)"]
stats = cache.step([np.unique(samples[best == j]) for j in range(n)])
print(f"\nactual ops under ESD dispatch: miss_pull={stats.miss_pull.sum()} "
      f"update_push={stats.update_push.sum()} evict_push={stats.evict_push.sum()}")
print(f"actual transmission cost: {stats.cost(t_tran):.5f} s")
