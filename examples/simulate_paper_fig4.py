"""Reproduce the paper's headline comparison (Fig. 4) at laptop scale.

  PYTHONPATH=src python examples/simulate_paper_fig4.py

Runs the paper-faithful simulator (8 edge workers, 4x 5 Gbps + 4x 0.5 Gbps,
BSP + on-demand sync) over a Criteo-shaped Zipf stream for ESD(alpha in
{1, 0.5, 0}), LAIA, HET, FAE and random dispatch; prints speedup and cost
reduction with LAIA as the reference, exactly as the paper reports them.
"""
import sys

sys.path.insert(0, "src")
from repro.core import SimConfig, simulate  # noqa: E402
from repro.data.synthetic import WORKLOADS  # noqa: E402

base = dict(workload=WORKLOADS["S2"], n_workers=8, batch_per_worker=64,
            cache_ratio=0.08, embedding_dim=512, iters=40, warmup=10)

results = {}
for mech, alpha in [("laia", 0), ("esd", 1.0), ("esd", 0.5), ("esd", 0.0),
                    ("het", 0), ("fae", 0), ("random", 0)]:
    name = f"ESD(a={alpha})" if mech == "esd" else mech.upper()
    results[name] = simulate(SimConfig(mechanism=mech, alpha=alpha, **base))
    print(f"ran {name}: cost={results[name].cost:.4f}s "
          f"itps={results[name].itps:.1f}")

ref = results["LAIA"]
print(f"\n{'mechanism':14s} {'speedup':>8s} {'cost_red':>9s} {'hit':>6s}")
for name, r in results.items():
    print(f"{name:14s} {r.itps / ref.itps:8.2f} "
          f"{(ref.cost - r.cost) / ref.cost:9.2%} {r.hit_ratio:6.1%}")
print("\npaper claims (testbed scale): ESD(a=1) up to 1.74x speedup and "
      "36.76% cost reduction vs LAIA; ordering ESD(1) > ESD(0.5) > ESD(0).")

# ---------------------------------------------------------------------------
# beyond-paper scenario: the V-space split over 2 parameter servers with
# skewed links (one 5 Gbps PS, one 0.5 Gbps).  The ps-aware Alg. 1 charges
# a miss at the OWNING shard's link, so ESD steers samples whose ids are
# homed on the slow PS toward workers that already cache them — random
# (and cost-blind greedy-by-hits) dispatch cannot.
from repro.core import hetero_ps_bandwidths  # noqa: E402

print("\nheterogeneous parameter servers (n_ps=2: one fast, one slow link)")
hps = dict(base, n_ps=2,
           ps_bandwidths=hetero_ps_bandwidths(base["n_workers"], 2))
hres = {}
for mech, alpha, kw in [("esd", 1.0, {}), ("esd", 0.0, {}), ("random", 0, {}),
                        ("het", 0, {"het_staleness": 2}), ("fae", 0, {})]:
    name = f"ESD(a={alpha})" if mech == "esd" else mech.upper()
    hres[name] = simulate(SimConfig(mechanism=mech, alpha=alpha, **kw, **hps))
href = hres["RANDOM"]
print(f"{'mechanism':14s} {'cost':>10s} {'cost_red':>9s} {'hit':>6s}")
for name, r in hres.items():
    print(f"{name:14s} {r.cost:10.4f} "
          f"{(href.cost - r.cost) / href.cost:9.2%} {r.hit_ratio:6.1%}")

# ---------------------------------------------------------------------------
# beyond-paper scenario: ragged exchange + capacity slack.  The hard m/n
# dispatch cap forces a balanced assignment; with the ragged wire path the
# cap can relax (cap_slack), the assignment skews toward cheap links, and
# the Alg.-1 objective drops — while the exchange ships bucketed blocks
# instead of worst-case uniform padding.
print("\nragged exchange + capacity slack (ESD a=0)")
print(f"{'config':22s} {'alg1_cost':>10s} {'wire_MB':>8s} {'pad_red':>8s}")
for label, kw in [("padded, hard cap", dict(exchange="padded")),
                  ("ragged, hard cap", dict(exchange="ragged")),
                  ("ragged, slack 0.5", dict(exchange="ragged", cap_slack=0.5))]:
    r = simulate(SimConfig(mechanism="esd", alpha=0.0, **kw, **base))
    ex = r.exchange
    print(f"{label:22s} {r.alg1_cost:10.4f} {ex['wire_bytes'] / 1e6:8.2f} "
          f"{ex['pad_reduction']:8.1%}")

# ---------------------------------------------------------------------------
# beyond-paper scenario: lookahead dispatch pipelining (repro.pipeline).
# Synchronous training pays decision + train per iteration; the pipelined
# runtime overlaps them (per-iteration time -> max of the two stages), and
# a W-batch lookahead window additionally shields soon-reused cache
# entries from eviction, cutting miss pulls — the headline step-time
# levers after the exchange.
print("\npipelined vs synchronous ESD (a=1: decision ~ a full train step)")
print(f"{'config':22s} {'itps':>7s} {'speedup':>8s} {'miss_ops':>9s} "
      f"{'hit':>6s}")
# tight LRU cache so eviction pressure exists — the regime where the
# lookahead window's Belady-graded shield can cut miss pulls
pbase = dict(base, alpha=1.0, mechanism="esd", cache_ratio=0.008,
             policy="lru")
pres = {}
for label, kw in [("synchronous", dict(pipeline_depth=1)),
                  ("pipelined", dict(pipeline_depth=2)),
                  ("pipelined + W=8", dict(pipeline_depth=2, lookahead=8))]:
    pres[label] = r = simulate(SimConfig(**kw, **pbase))
sref = pres["synchronous"]
for label, r in pres.items():
    print(f"{label:22s} {r.itps:7.2f} {r.itps / sref.itps:8.2f} "
          f"{r.pipeline['miss_pull_total']:9d} {r.hit_ratio:6.1%}")
print("pipelined per-iteration time ~ max(train, decision): "
      f"{pres['pipelined'].per_iter_time.mean() * 1e3:.1f} ms vs max "
      f"{max(pres['pipelined'].pipeline['train_stage_mean_s'], pres['pipelined'].pipeline['decision_stage_mean_s']) * 1e3:.1f} ms "
      f"(synchronous sums: {sref.per_iter_time.mean() * 1e3:.1f} ms)")
