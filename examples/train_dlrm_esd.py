"""End-to-end driver: train a WDL DLRM for a few hundred steps with ESD
dispatch running inside the jitted step, and compare the accumulated
transmission cost of HybridDis Opt (alpha=1) against Heu-only (alpha=0).

  PYTHONPATH=src python examples/train_dlrm_esd.py [--steps 200] [--tiny]

This is the "train a ~100M model for a few hundred steps" driver: with the
default S1 workload the WDL embedding table is ~502k rows x 512 dims
(~257M params).  Use --tiny for a quick run.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")
from repro.launch import train as train_mod  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--bpw", type=int, default=64)
    args = ap.parse_args()

    arch = "wdl-tiny" if args.tiny else "wdl-s1"
    runs = {}
    for label, alpha in [("esd_opt(a=1)", 1.0), ("esd_heu(a=0)", 0.0)]:
        print(f"== {label} ==")
        metrics = train_mod.main(
            ["--arch", arch, "--steps", str(args.steps),
             "--batch-per-worker", str(args.bpw), "--log-every", "50",
             "--esd-alpha", str(alpha)])
        costs = [m.get("cost", 0.0) for m in metrics[5:]]   # skip warm-up
        losses = [m["loss"] for m in metrics]
        runs[label] = dict(cost=float(np.sum(costs)),
                           final_loss=float(np.mean(losses[-10:])))
        print(f"{label}: total transmission cost {runs[label]['cost']:.4f} s, "
              f"final loss {runs[label]['final_loss']:.4f}")

    red = 1 - runs["esd_opt(a=1)"]["cost"] / max(runs["esd_heu(a=0)"]["cost"],
                                                 1e-12)
    print(f"\nESD Opt vs Heu cost reduction: {red:.1%}")
    print("(losses match: dispatch preserves the model — paper Sec. 3)")


if __name__ == "__main__":
    main()
